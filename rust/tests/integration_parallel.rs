//! Thread-count invariance suite for the `util::pool` execution layer
//! (tier-1, DESIGN.md §Parallel).
//!
//! The worker pool promises that parallelism is a **wall-clock knob
//! only**: every functional output — dataflow `AttnOut`s, full-block
//! decode logits/cache rows, greedy token streams through the serving
//! engine — is `f32::to_bits`-identical at every pool size, because the
//! pool only distributes *independent outputs* across threads and every
//! merge runs on the calling thread in the serial code's order. This
//! suite pins that contract across pool sizes 1/2/4/8, for MHA and MLA
//! geometries, on both transports, plus the pool's own unit semantics
//! (empty ranges, more threads than items, panic propagation) and the
//! persistent-worker lifecycle: the same resident threads serve
//! thousands of dispatches, a task panic leaves the pool usable (not
//! poisoned), `Drop` joins every worker, and an explicit
//! `CLUSTERFUSION_THREADS` width beats the `MIN_TASK_MACS` auto-gate.
//!
//! If this suite trips, a kernel raced on shared state or a merge left
//! the serial order. Fix the kernel/merge, not the test.

use std::panic::{catch_unwind, AssertUnwindSafe};

use clusterfusion::clustersim::block::BlockModel;
use clusterfusion::clustersim::collective::Transport;
use clusterfusion::clustersim::dataflow::reference::AttnOut;
use clusterfusion::clustersim::dataflow::{
    block_isolated, mla, reference, split_head, split_token, PackedMhaWeights, PackedMlaWeights,
};
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::Engine;
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::models::ModelConfig;
use clusterfusion::util::pool::Pool;
use clusterfusion::util::rng::Rng;

const POOL_SIZES: [usize; 4] = [1, 2, 4, 8];
const TRANSPORTS: [Transport; 2] = [Transport::Dsmem, Transport::GlobalMemory];

// ---------------------------------------------------------------------------
// Seeded cases (mirrors the in-crate `dataflow::testutil` generators,
// which are not exported to integration tests).
// ---------------------------------------------------------------------------

struct MhaCase {
    b: usize,
    d: usize,
    nh: usize,
    dh: usize,
    s: usize,
    hidden: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    pos: Vec<usize>,
}

fn mha_case(seed: u64, b: usize, nh: usize, dh: usize, s: usize, d: usize) -> MhaCase {
    let mut rng = Rng::seed_from_u64(seed);
    let h = nh * dh;
    let mut v = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    };
    let hidden = v(b * d, 2.0);
    let wq = v(d * h, 0.4);
    let wk = v(d * h, 0.4);
    let wv = v(d * h, 0.4);
    let wo = v(h * d, 0.4);
    let k_cache = v(b * s * h, 2.0);
    let v_cache = v(b * s * h, 2.0);
    let mut rng2 = Rng::seed_from_u64(seed ^ 0xdead);
    let pos = (0..b).map(|_| rng2.range(0, s)).collect();
    MhaCase { b, d, nh, dh, s, hidden, wq, wk, wv, wo, k_cache, v_cache, pos }
}

struct MlaCase {
    b: usize,
    d: usize,
    nh: usize,
    l: usize,
    dh: usize,
    s: usize,
    hidden: Vec<f32>,
    wq: Vec<f32>,
    wkv: Vec<f32>,
    w_down: Vec<f32>,
    wo: Vec<f32>,
    kv_cache: Vec<f32>,
    pos: Vec<usize>,
}

fn mla_case(seed: u64, b: usize, nh: usize, l: usize, dh: usize, s: usize, d: usize) -> MlaCase {
    let mut rng = Rng::seed_from_u64(seed);
    let mut v = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    };
    let hidden = v(b * d, 2.0);
    let wq = v(d * nh * l, 0.4);
    let wkv = v(d * l, 0.4);
    let w_down = v(nh * l * dh, 0.4);
    let wo = v(nh * dh * d, 0.4);
    let kv_cache = v(b * s * l, 2.0);
    let mut rng2 = Rng::seed_from_u64(seed ^ 0xbeef);
    let pos = (0..b).map(|_| rng2.range(0, s)).collect();
    MlaCase { b, d, nh, l, dh, s, hidden, wq, wkv, w_down, wo, kv_cache, pos }
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x:?} vs {y:?}");
    }
}

fn assert_out_bits(got: &AttnOut, want: &AttnOut, what: &str) {
    assert_bits(&got.out, &want.out, &format!("{what}.out"));
    assert_bits(&got.k_new, &want.k_new, &format!("{what}.k_new"));
    assert_bits(&got.v_new, &want.v_new, &format!("{what}.v_new"));
}

fn env() -> (Hardware, Noc) {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    (hw, noc)
}

// ---------------------------------------------------------------------------
// Pool unit semantics
// ---------------------------------------------------------------------------

#[test]
fn pool_empty_range_and_single_item_run_inline() {
    let pool = Pool::new(8);
    assert!(pool.run_map(0, |i| i).is_empty());
    assert!(pool.run_ranges(0, |lo, hi| (lo, hi)).is_empty());
    // a single item runs on the calling thread even on a wide pool
    let here = std::thread::current().id();
    let ids = pool.run_map(1, |_| std::thread::current().id());
    assert_eq!(ids, vec![here]);
}

#[test]
fn pool_handles_fewer_items_than_threads() {
    let pool = Pool::new(16);
    let got = pool.run_map(5, |i| 10 * i);
    assert_eq!(got, vec![0, 10, 20, 30, 40]);
    let ranges = pool.run_ranges(3, |lo, hi| (lo, hi));
    assert_eq!(ranges, vec![(0, 1), (1, 2), (2, 3)]);
}

#[test]
fn pool_propagates_task_panics() {
    for threads in POOL_SIZES {
        let pool = Pool::new(threads);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |i| {
                if i == 11 {
                    panic!("boom at 11");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the caller at threads={threads}");
    }
}

// ---------------------------------------------------------------------------
// Persistent-worker lifecycle
// ---------------------------------------------------------------------------

#[test]
fn pool_reuses_workers_across_thousands_of_calls() {
    // Persistent workers: thousands of dispatches ride the same threads
    // spawned once in `Pool::new` (the point of the rewrite — per-call
    // scoped spawns paid ~163 µs/worker/call), and the counters record
    // exactly one dispatch per call.
    let pool = Pool::new(4);
    let ids = |_: usize| std::thread::current().id();
    let first = pool.run_map(4, ids);
    let distinct: std::collections::HashSet<_> = first.iter().collect();
    assert_eq!(distinct.len(), 4, "4 items on a 4-pool use 4 distinct threads");
    for call in 0..2_000 {
        assert_eq!(pool.run_map(4, ids), first, "call {call}: worker identity must be stable");
    }
    let s = pool.stats();
    assert_eq!(s.dispatches, 2_001);
    assert_eq!(s.tasks, 4 * 2_001);
    assert_eq!(s.queue_depth, 0, "idle between dispatches");
}

#[test]
fn pool_stays_usable_after_task_panic() {
    // Pinned lifecycle choice (referenced by `util::pool`'s module docs):
    // workers catch task panics and never die, so the pool is usable —
    // not poisoned — after the panic reaches the caller. Repeatedly.
    let pool = Pool::new(4);
    for round in 0..3 {
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("round {round}");
                }
            });
        }));
        assert!(r.is_err(), "round {round}: panic must reach the caller");
        assert_eq!(
            pool.run_map(8, |i| i + 1),
            (1..=8).collect::<Vec<_>>(),
            "round {round}: pool must keep working after a task panic"
        );
    }
}

/// Live `cf-pool-*` worker threads of this process (Linux: every thread
/// is a `/proc/self/task` entry until it exits and is joined).
#[cfg(target_os = "linux")]
fn resident_worker_threads() -> usize {
    let mut n = 0;
    if let Ok(dir) = std::fs::read_dir("/proc/self/task") {
        for e in dir.flatten() {
            if let Ok(comm) = std::fs::read_to_string(e.path().join("comm")) {
                if comm.trim().starts_with("cf-pool-") {
                    n += 1;
                }
            }
        }
    }
    n
}

#[test]
#[cfg(target_os = "linux")]
fn drop_joins_all_resident_workers() {
    // `Drop` must signal shutdown and join every worker: no parked
    // threads may outlive the pool. Width 65 (64 workers) dwarfs any
    // pool a concurrently running test holds (≤ 16), so the count
    // deltas are unambiguous even with libtest parallelism.
    let width = 65usize;
    let before = resident_worker_threads();
    let pool = Pool::new(width);
    assert!(
        resident_worker_threads() >= before + width - 1,
        "Pool::new must spawn its workers eagerly"
    );
    pool.run(width * 4, |_| {}); // workers actually exercised
    // join is synchronous: drop returning at all proves every worker
    // exited and was reaped — a stuck worker would hang this test
    drop(pool);
    let after = resident_worker_threads();
    assert!(
        after <= before + 32,
        "workers must be joined on drop: {before} before, {after} after"
    );
}

#[test]
fn env_width_beats_the_auto_gate() {
    // `CLUSTERFUSION_THREADS` is an explicit ask: it must win over the
    // `MIN_TASK_MACS` work-size gate that keeps auto-sized pools serial
    // on micro models (the CI matrix legs depend on this). micro-llama's
    // cluster-block tasks are ~KMACs, far below the gate.
    let saved = std::env::var("CLUSTERFUSION_THREADS").ok();
    std::env::set_var("CLUSTERFUSION_THREADS", "4");
    let auto = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, 0).unwrap();
    let env_width = auto.threads();
    match &saved {
        Some(v) => std::env::set_var("CLUSTERFUSION_THREADS", v),
        None => std::env::remove_var("CLUSTERFUSION_THREADS"),
    }
    assert_eq!(env_width, 4, "env width must beat the MIN_TASK_MACS gate");
}

// ---------------------------------------------------------------------------
// Dataflow AttnOut invariance across pool sizes
// ---------------------------------------------------------------------------

#[test]
fn split_token_bitexact_across_pool_sizes() {
    let (hw, noc) = env();
    // (seed, b, nh, dh, s, d, n): cluster sizes that give the block axis
    // 2–8 parallel items, batch > 1, both rope states below
    for &(seed, b, nh, dh, s, d, n) in &[
        (101u64, 2usize, 2usize, 8usize, 16usize, 16usize, 4usize),
        (102, 1, 3, 16, 32, 24, 8),
        (103, 2, 2, 8, 16, 16, 2),
    ] {
        let c = mha_case(seed, b, nh, dh, s, d);
        let w = PackedMhaWeights::pack(&c.wq, &c.wk, &c.wv, &c.wo, c.d, c.nh * c.dh);
        for transport in TRANSPORTS {
            for rope in [None, Some(10000.0f32)] {
                let run = |pool: &Pool| {
                    split_token::execute_packed_rope_on(
                        pool, &c.hidden, &w, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d, c.nh,
                        c.dh, c.s, n, transport, &hw, &noc, rope,
                    )
                    .0
                };
                // the serial wrapper is the reference
                let want = split_token::execute_packed_rope(
                    &c.hidden, &w, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d, c.nh, c.dh, c.s,
                    n, transport, &hw, &noc, rope,
                )
                .0;
                for threads in POOL_SIZES {
                    let got = run(&Pool::new(threads));
                    let what = format!(
                        "split_token seed={seed} n={n} t={threads} {transport:?} rope={rope:?}"
                    );
                    assert_out_bits(&got, &want, &what);
                }
            }
        }
    }
}

#[test]
fn mla_bitexact_across_pool_sizes() {
    let (hw, noc) = env();
    for &(seed, b, nh, l, dh, s, d, n) in &[
        (201u64, 2usize, 2usize, 16usize, 8usize, 16usize, 16usize, 4usize),
        (202, 1, 2, 32, 8, 32, 32, 8),
    ] {
        let c = mla_case(seed, b, nh, l, dh, s, d);
        let w = PackedMlaWeights::pack(&c.wq, &c.wkv, &c.wo, c.d, c.nh, c.l, c.dh);
        for transport in TRANSPORTS {
            let want = mla::execute_packed(
                &c.hidden, &w, &c.w_down, &c.kv_cache, &c.pos, c.b, c.d, c.nh, c.l, c.dh, c.s,
                n, transport, &hw, &noc,
            )
            .0;
            for threads in POOL_SIZES {
                let got = mla::execute_packed_on(
                    &Pool::new(threads), &c.hidden, &w, &c.w_down, &c.kv_cache, &c.pos, c.b,
                    c.d, c.nh, c.l, c.dh, c.s, n, transport, &hw, &noc,
                )
                .0;
                let what = format!("mla seed={seed} n={n} t={threads} {transport:?}");
                assert_bits(&got.out, &want.out, &format!("{what}.out"));
                assert_bits(&got.k_new, &want.k_new, &format!("{what}.kv_new"));
            }
        }
    }
}

#[test]
fn split_head_bitexact_across_pool_sizes() {
    let (hw, noc) = env();
    for &(seed, b, nh, dh, s, d, n) in
        &[(301u64, 2usize, 3usize, 8usize, 12usize, 16usize, 4usize), (302, 1, 5, 16, 20, 24, 2)]
    {
        let c = mha_case(seed, b, nh, dh, s, d);
        for transport in TRANSPORTS {
            let run = |pool: &Pool| {
                split_head::execute_on(
                    pool, &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache,
                    &c.pos, c.b, c.d, c.nh, c.dh, c.s, n, transport, &hw, &noc,
                )
            };
            let (want, want_rep) = split_head::execute(
                &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos, c.b,
                c.d, c.nh, c.dh, c.s, n, transport, &hw, &noc,
            );
            for threads in POOL_SIZES {
                let (got, rep) = run(&Pool::new(threads));
                let what = format!("split_head seed={seed} n={n} t={threads} {transport:?}");
                assert_out_bits(&got, &want, &what);
                // the per-head dsmem accounting must keep the serial f64
                // accumulation sequence, bit for bit
                assert_eq!(rep.dsmem_bytes.to_bits(), want_rep.dsmem_bytes.to_bits(), "{what}");
            }
        }
    }
}

#[test]
fn block_isolated_and_reference_bitexact_across_pool_sizes() {
    for &(seed, b, nh, dh, s, d) in
        &[(401u64, 2usize, 3usize, 8usize, 20usize, 24usize), (402, 1, 6, 4, 12, 16)]
    {
        let c = mha_case(seed, b, nh, dh, s, d);
        let (want_bi, _) = block_isolated::execute(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d,
            c.nh, c.dh, c.s,
        );
        let want_ref = reference::attention_block_ref(
            &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d,
            c.nh, c.dh, c.s,
        );
        for threads in POOL_SIZES {
            let pool = Pool::new(threads);
            let (got_bi, _) = block_isolated::execute_on(
                &pool, &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.b, c.d, c.nh, c.dh, c.s,
            );
            assert_out_bits(&got_bi, &want_bi, &format!("block_isolated seed={seed} t={threads}"));
            let got_ref = reference::attention_block_ref_on(
                &pool, &c.hidden, &c.wq, &c.wk, &c.wv, &c.wo, &c.k_cache, &c.v_cache, &c.pos,
                c.b, c.d, c.nh, c.dh, c.s,
            );
            assert_out_bits(&got_ref, &want_ref, &format!("reference seed={seed} t={threads}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Full-block decode and greedy streams
// ---------------------------------------------------------------------------

/// Seeded non-trivial cache planes in the engine's (L, bucket, S, re)
/// gather layout, with per-slot positions inside the cache.
fn seeded_planes(model: &BlockModel, bucket: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<i32>) {
    let cfg = model.config();
    let mut rng = Rng::seed_from_u64(seed);
    let plane_len = cfg.n_layers * bucket * cfg.max_seq * model.row_elems();
    let planes = (0..model.planes())
        .map(|_| (0..plane_len).map(|_| (rng.f32() - 0.5) * 2.0).collect())
        .collect();
    let pos = (0..bucket).map(|bi| ((bi * 3 + 2) % cfg.max_seq) as i32).collect();
    (planes, pos)
}

#[test]
fn block_decode_step_bitexact_across_pool_sizes() {
    for cfg in [ModelConfig::micro_llama(), ModelConfig::micro_mla()] {
        let model = BlockModel::from_config(&cfg, 42, 2);
        let bucket = 2usize;
        let (planes, pos) = seeded_planes(&model, bucket, 9);
        let tokens = [7i32, 13];
        let (want_logits, want_rows) = model.decode_step(&tokens, &pos, &planes, bucket);
        for threads in POOL_SIZES {
            let pool = Pool::new(threads);
            let (logits, rows, greedy) =
                model.decode_step_on(&pool, &tokens, &pos, &planes, bucket);
            let what = format!("{} t={threads}", cfg.name);
            assert_bits(&logits, &want_logits, &format!("{what}.logits"));
            assert_eq!(rows.len(), want_rows.len());
            for (p, (got, want)) in rows.iter().zip(&want_rows).enumerate() {
                assert_bits(got, want, &format!("{what}.plane{p}"));
            }
            // sharded-argmax merge == full-row argmax, at every pool size
            for bi in 0..bucket {
                let row = &logits[bi * cfg.vocab..(bi + 1) * cfg.vocab];
                assert_eq!(greedy[bi], clusterfusion::runtime::argmax(row), "{what} slot {bi}");
            }
        }
    }
}

#[test]
fn greedy_token_streams_identical_across_thread_counts() {
    for model_name in ["micro-llama", "micro-mla"] {
        let run = |threads: usize| -> Vec<(u64, Vec<i32>)> {
            let backend =
                FunctionalBackend::from_model_name_on(model_name, 42, 2, threads).unwrap();
            let mut engine = Engine::new(backend, 64, 8, 1.0);
            // prompts end in distinct tokens so streams cannot trivially
            // coincide (a random tied-embedding transformer parrots)
            for id in 0..3u64 {
                engine.submit(Request::new(id, vec![5, 9, 1 + id as i32], 5));
            }
            engine.run_to_completion(256).unwrap();
            let mut streams: Vec<(u64, Vec<i32>)> = engine
                .take_events()
                .into_iter()
                .filter_map(|e| match e {
                    Event::Finished { id, generated, .. } => Some((id, generated)),
                    _ => None,
                })
                .collect();
            streams.sort();
            streams
        };
        let want = run(1);
        assert_eq!(want.len(), 3, "{model_name}: every request must finish");
        for threads in [2usize, 4, 8] {
            assert_eq!(
                run(threads),
                want,
                "{model_name}: greedy streams must be identical at {threads} threads"
            );
        }
    }
}

#[test]
fn auto_pool_matches_serial_on_a_dataflow() {
    // Pool::auto() honours CLUSTERFUSION_THREADS (the CI matrix leg) or
    // the host width — whatever it resolves to, outputs match serial.
    let (hw, noc) = env();
    let c = mha_case(777, 2, 2, 8, 16, 16);
    let w = PackedMhaWeights::pack(&c.wq, &c.wk, &c.wv, &c.wo, c.d, c.nh * c.dh);
    let auto = Pool::auto();
    assert!(auto.threads() >= 1);
    let got = split_token::execute_packed_on(
        &auto, &c.hidden, &w, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d, c.nh, c.dh, c.s, 4,
        Transport::Dsmem, &hw, &noc,
    )
    .0;
    let want = split_token::execute_packed(
        &c.hidden, &w, &c.k_cache, &c.v_cache, &c.pos, c.b, c.d, c.nh, c.dh, c.s, 4,
        Transport::Dsmem, &hw, &noc,
    )
    .0;
    assert_out_bits(&got, &want, &format!("auto pool ({} threads)", auto.threads()));
}
