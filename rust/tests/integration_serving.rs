//! Coordinator integration tests over the mock backend: whole-server
//! behaviour at scales the PJRT tests can't afford, plus trace replay.

use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::request::{Event, FinishReason, Request, SamplingParams};
use clusterfusion::coordinator::router::Router;
use clusterfusion::coordinator::server::Server;
use clusterfusion::util::rng::Rng;
use clusterfusion::workload::{SeqlenDist, Trace};

fn big_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 512, n_layers: 4, row_elems: 32, planes: 2, max_seq: 256 },
        vec![1, 4, 8],
    )
}

#[test]
fn hundred_request_trace_completes() {
    let mut engine = Engine::new(big_mock(), 1024, 16, 0.5);
    let trace = Trace::poisson(100, 50.0, SeqlenDist::ShareGpt, (2, 12), 128, 9);
    let mut rng = Rng::seed_from_u64(1);
    for r in &trace.requests {
        let prompt: Vec<i32> =
            (0..r.prompt_len.clamp(1, 32)).map(|_| rng.below(512) as i32).collect();
        engine.submit(Request::new(r.id, prompt, r.gen_len));
    }
    engine.run_to_completion(100_000).unwrap();
    let finished = engine
        .take_events()
        .iter()
        .filter(|e| matches!(e, Event::Finished { .. }))
        .count();
    assert_eq!(finished, 100);
    assert_eq!(engine.pool.used_pages(), 0, "no leaked pages");
    assert_eq!(engine.timings().len(), 100);
    // batching efficiency: total steps far below sum of per-request steps
    let serial_steps: usize =
        engine.timings().iter().map(|t| t.prompt_len.min(32) + t.generated).sum();
    assert!(
        (engine.steps as usize) < serial_steps / 2,
        "batching should at least halve steps: {} vs {serial_steps}",
        engine.steps
    );
}

#[test]
fn generated_token_counts_match_sampling_params() {
    let mut engine = Engine::new(big_mock(), 1024, 16, 0.5);
    for id in 0..20u64 {
        let gen = 1 + (id as usize % 7);
        let mut req = Request::new(id, vec![1; 3], gen);
        req.sampling = SamplingParams { max_new_tokens: gen, ..Default::default() };
        engine.submit(req);
    }
    engine.run_to_completion(10_000).unwrap();
    for t in engine.timings() {
        assert_eq!(t.generated, 1 + (t.id as usize % 7), "req {}", t.id);
    }
}

#[test]
fn server_under_concurrent_submitters() {
    let engine = Engine::new(big_mock(), 1024, 16, 0.5);
    let server = std::sync::Arc::new(Server::spawn(engine));
    let mut joins = Vec::new();
    for thread in 0..4u64 {
        let server = server.clone();
        joins.push(std::thread::spawn(move || {
            let mut done = 0;
            for i in 0..10u64 {
                let id = thread * 100 + i;
                let rx = server.submit(Request::new(id, vec![1, 2, 3], 4)).unwrap();
                let evs: Vec<Event> = rx.iter().collect();
                assert!(matches!(evs.last().unwrap(), Event::Finished { .. }));
                done += 1;
            }
            done
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 40);
    let server = std::sync::Arc::try_unwrap(server).ok().expect("sole owner");
    let report = server.shutdown().unwrap();
    assert_eq!(report.timings.len(), 40);
    assert_eq!(report.tokens_out, 160);
}

#[test]
fn router_plus_engines_spread_load() {
    // simulate a 4-replica deployment: route, then drive each replica
    let mut router = Router::new(4, 100);
    let mut engines: Vec<Engine<MockBackend>> =
        (0..4).map(|_| Engine::new(big_mock(), 512, 16, 0.5)).collect();
    for id in 0..40u64 {
        let req = Request::new(id, vec![2; 4], 4);
        let route = router.route(&req).unwrap();
        router.on_started(id);
        engines[route.replica].submit(req);
    }
    let mut counts = Vec::new();
    for e in engines.iter_mut() {
        e.run_to_completion(10_000).unwrap();
        let n = e.timings().len();
        for t in e.timings() {
            router.on_finished(t.id);
        }
        counts.push(n);
    }
    assert_eq!(counts.iter().sum::<usize>(), 40);
    assert!(counts.iter().all(|&c| c == 10), "least-loaded spread: {counts:?}");
    let stats = router.stats();
    assert_eq!(stats.routed, 40);
    assert_eq!(stats.spurious_starts + stats.spurious_finishes, 0);
    for i in 0..4 {
        assert_eq!(router.load(i).tokens, 0, "replica {i} footprint returned");
    }
}

#[test]
fn preempted_requests_still_produce_correct_token_counts() {
    // pool deliberately too small: 8 pages x 8 tokens = 64 slots for
    // 8 requests x up to 24 tokens = 192 worst case
    let mut engine = Engine::new(big_mock(), 8, 8, 0.2);
    for id in 0..8u64 {
        engine.submit(Request::new(id, vec![3; 8], 16));
    }
    engine.run_to_completion(100_000).unwrap();
    assert_eq!(engine.timings().len(), 8, "all requests completed");
    assert!(engine.preemptions > 0, "pressure must trigger preemption");
    for t in engine.timings() {
        assert_eq!(t.generated, 16, "req {} token count intact", t.id);
    }
    assert_eq!(engine.pool.used_pages(), 0);
}

#[test]
fn determinism_under_identical_seeds() {
    let run = || {
        let mut engine = Engine::new(big_mock(), 256, 16, 0.5);
        for id in 0..10u64 {
            engine.submit(Request::new(id, vec![(id % 9) as i32 + 1; 4], 6));
        }
        engine.run_to_completion(10_000).unwrap();
        engine
            .take_events()
            .iter()
            .filter_map(|e| match e {
                Event::Finished { id, generated, .. } => Some((*id, generated.clone())),
                _ => None,
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn finish_reasons_are_accurate() {
    let mut engine = Engine::new(big_mock(), 1024, 16, 0.5);
    // length-bound
    engine.submit(Request::new(1, vec![1], 2));
    // eos-bound: mock emits (token + pos) % vocab; prompt [1] at pos 0 ->
    // first token 1; next input 1 at pos 1 -> 2; set eos = 2
    let mut r2 = Request::new(2, vec![1], 50);
    r2.sampling.eos_token = Some(2);
    engine.submit(r2);
    // cache-bound: prompt + gen exceed max_seq 256, which the front door
    // now refuses at submit — inject past it to exercise the in-flight
    // backstop (a sequence reaching max_seq finishes, never stalls)
    engine.batcher.submit(Request::new(3, vec![1; 10], 10_000), 0);
    // front-door-bound: the same oversized shape via submit is rejected
    // up front with an event and no execution
    engine.submit(Request::new(4, vec![1; 10], 10_000));
    engine.run_to_completion(100_000).unwrap();
    let mut reasons = std::collections::HashMap::new();
    for ev in engine.take_events() {
        if let Event::Finished { id, reason, .. } = ev {
            reasons.insert(id, reason);
        }
    }
    assert_eq!(reasons[&1], FinishReason::Length);
    assert_eq!(reasons[&2], FinishReason::Eos);
    assert_eq!(reasons[&3], FinishReason::CacheFull);
    assert_eq!(reasons[&4], FinishReason::Rejected);
    assert_eq!(engine.rejected_too_long, 1);
    assert_eq!(engine.timings().len(), 3, "rejected request records no timing");
}
