//! Observability integration tests (`obs`): the deterministic tracing
//! and metrics plane.
//!
//! Three acceptance properties:
//!
//! 1. **Byte-stability** — the Chrome trace and Prometheus exports of a
//!    virtual-clock fleet replay are byte-identical across runs and
//!    host pool widths {1, 4}, per replica count {1, 2}. (The replica
//!    index is the Chrome `pid`, so traces from *different* replica
//!    counts legitimately differ — the invariant is within a count.)
//! 2. **Registry = reports** — after the pinned 450 rps crash scenario
//!    (`integration_fleet`'s tier-1 scenario), every registry counter
//!    equals its `FleetReport`/`ReplayReport`/`RouterStats` field, and
//!    the fleet-event *trace instants* (crash/detect/evacuate/retry)
//!    count out to the same numbers — the co-location guarantee.
//! 3. **Well-formed JSON** — the Chrome export parses back through
//!    `util::json` and spans nest by containment on every `(pid, tid)`
//!    track (end ≥ start; children inside parents).

use std::collections::BTreeMap;

use clusterfusion::clustersim::block::FusionScope;
use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::fleet::{FaultPlan, Fleet, FleetOptions, FleetReport};
use clusterfusion::coordinator::functional_backend::FunctionalBackend;
use clusterfusion::coordinator::request::Request;
use clusterfusion::loadgen::{self, ServiceModel};
use clusterfusion::models::ModelConfig;
use clusterfusion::obs::{kernel_stages_for, Obs, TracePhase};
use clusterfusion::util::clock::SharedClock;
use clusterfusion::util::json::Json;
use clusterfusion::workload::{SeqlenDist, Trace};

// The pinned tier-1 crash scenario, identical to integration_fleet.
const N_REQUESTS: usize = 160;
const TRACE_SEED: u64 = 42;
const SYNTH_SEED: u64 = 7;
const CRASH_RPS: f64 = 450.0;

fn load_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
        vec![1, 2, 4, 8],
    )
}

fn svc() -> ServiceModel {
    ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 }
}

fn mk_mock_engine(clock: SharedClock) -> Engine<MockBackend> {
    let mut e = Engine::with_clock(load_mock(), 40, 4, 0.5, clock);
    e.set_prefill_chunk(4);
    e
}

fn load_requests(rps: f64) -> Vec<Request> {
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED)
}

/// The pinned crash replay with a sink attached (kernel schedule
/// installed so step spans expand into per-kernel children).
fn crash_replay_with_obs() -> (Obs, FleetReport) {
    let plan = FaultPlan::parse("crash:0@120000").expect("plan");
    let mut fleet = Fleet::build(2, plan, FleetOptions::default(), mk_mock_engine);
    let obs = Obs::new();
    obs.set_kernel_stages(kernel_stages_for(
        &ModelConfig::micro_llama(),
        64,
        FusionScope::FullBlockFused,
        2,
    ));
    fleet.set_obs(obs.clone());
    let report = fleet.replay(&load_requests(CRASH_RPS), &svc(), 1_000_000).expect("fleet replay");
    (obs, report)
}

// ---------------------------------------------------------------------
// 1. byte-stability across runs and host pool widths
// ---------------------------------------------------------------------

/// Functional micro-llama fleet (real numerics) on `threads` host pool
/// workers; returns both exports. Mirrors integration_fleet's
/// pool-width-invariance scenario, with the sink attached.
fn functional_fleet_exports(replicas: usize, threads: usize) -> (String, String) {
    let mut requests: Vec<Request> =
        (0..10u64).map(|i| Request::new(i, vec![3 + (i as i32 % 7); 6], 5)).collect();
    for (i, r) in requests.iter_mut().enumerate() {
        r.arrival_us = i as u64 * 2_000;
    }
    let mut fleet = Fleet::build(replicas, FaultPlan::none(), FleetOptions::default(), |clock| {
        let backend = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, threads)
            .expect("micro-llama materializes");
        let mut e = Engine::with_clock(backend, 64, 8, 1.0, clock);
        e.set_prefill_chunk(4);
        e
    });
    let obs = Obs::new();
    obs.set_kernel_stages(kernel_stages_for(
        &ModelConfig::micro_llama(),
        64,
        FusionScope::FullBlockFused,
        2,
    ));
    fleet.set_obs(obs.clone());
    fleet.replay(&requests, &svc(), 100_000).expect("fleet replay");
    (obs.chrome_trace(), obs.prometheus())
}

#[test]
fn trace_exports_are_byte_identical_across_runs_and_pools() {
    for replicas in [1usize, 2] {
        let (trace0, prom0) = functional_fleet_exports(replicas, 1);
        assert!(trace0.contains("\"cat\":\"kernel\""), "kernel child spans must be present");
        assert!(trace0.contains("\"cat\":\"request\""), "request lifecycle spans must be present");
        assert!(prom0.contains("# TYPE engine_steps_total counter"), "{prom0}");
        for threads in [1usize, 4] {
            let (t, p) = functional_fleet_exports(replicas, threads);
            assert_eq!(
                trace0, t,
                "replicas={replicas} threads={threads}: trace must be byte-stable"
            );
            assert_eq!(
                prom0, p,
                "replicas={replicas} threads={threads}: metrics must be byte-stable"
            );
        }
    }
}

#[test]
fn mock_crash_trace_is_byte_identical_across_runs() {
    let (a, _) = crash_replay_with_obs();
    let (b, _) = crash_replay_with_obs();
    assert_eq!(a.chrome_trace(), b.chrome_trace(), "crash trace must replay byte-identically");
    assert_eq!(a.prometheus(), b.prometheus());
}

// ---------------------------------------------------------------------
// 2. registry counters == report fields == trace instant counts
// ---------------------------------------------------------------------

#[test]
fn registry_and_trace_instants_match_the_pinned_crash_report() {
    let (obs, report) = crash_replay_with_obs();
    assert_eq!(report.crashed, vec![0], "scenario: replica 0 crashes exactly once");
    assert!(report.evacuated >= 1, "the 120 ms crash must land with work in flight");

    // The fleet-event instants in the trace count out to the report —
    // emission is co-located with every counter increment.
    let events = obs.events();
    let instants =
        |name: &str| events.iter().filter(|e| e.cat == "fleet" && e.name == name).count() as u64;
    assert_eq!(instants("crash"), report.crashed.len() as u64);
    assert_eq!(instants("evacuate"), report.evacuated);
    assert_eq!(instants("retry"), report.retries);
    assert_eq!(instants("failed"), report.failed.len() as u64);
    assert_eq!(instants("detect"), report.unhealthy_transitions);
    assert_eq!(instants("recover"), report.recovered);

    // Registry counters — the inline-incremented fleet series are never
    // re-set at the sync point, so equality here verifies the inline
    // sites themselves.
    let reg = obs.registry();
    assert_eq!(reg.counter("fleet_crashes_total"), report.crashed.len() as u64);
    assert_eq!(reg.counter("fleet_evacuated_total"), report.evacuated);
    assert_eq!(reg.counter("fleet_retries_total"), report.retries);
    assert_eq!(reg.counter("fleet_failed_total"), report.failed.len() as u64);
    assert_eq!(reg.counter("fleet_unhealthy_transitions_total"), report.unhealthy_transitions);
    assert_eq!(reg.counter("fleet_recovered_total"), report.recovered);
    assert_eq!(reg.counter("fleet_routed_total"), report.routed);
    assert_eq!(reg.counter("fleet_router_rejected_total"), report.router_rejected);
    assert_eq!(reg.counter("fleet_deadline_expired_total"), report.deadline_expired);

    // Router ledger.
    let rs = report.router_stats;
    assert_eq!(reg.counter("router_routed_total"), rs.routed);
    assert_eq!(reg.counter("router_rejected_total"), rs.rejected);
    assert_eq!(reg.counter("router_failed_total"), rs.failed);
    assert_eq!(reg.counter("router_spurious_starts_total"), rs.spurious_starts);
    assert_eq!(reg.counter("router_spurious_finishes_total"), rs.spurious_finishes);
    assert_eq!(reg.counter("router_spurious_fails_total"), rs.spurious_fails);
    assert_eq!(reg.counter("router_spurious_routes_total"), rs.spurious_routes);

    // Per-replica engine counters against the per-replica ReplayReports.
    for (i, r) in report.replicas.iter().enumerate() {
        let c = |name: &str| reg.counter(&format!("{name}{{replica=\"{i}\"}}"));
        assert_eq!(c("engine_steps_total"), r.steps, "replica {i} steps");
        assert_eq!(c("engine_tokens_out_total"), r.tokens_out, "replica {i} tokens");
        assert_eq!(c("engine_preemptions_total"), r.preemptions, "replica {i} preemptions");
    }

    // One end-to-end latency sample per completed request.
    let h = reg.histogram("request_e2e_ms").expect("e2e histogram exists");
    assert_eq!(h.count(), report.completed() as u64);

    // The snapshot renders the consolidated series.
    let prom = obs.prometheus();
    assert!(prom.contains("# TYPE fleet_evacuated_total counter"), "{prom}");
    assert!(prom.contains("# TYPE request_e2e_ms histogram"), "{prom}");
}

#[test]
fn loadgen_replay_syncs_engine_counters_into_the_registry() {
    // The single-engine replay driver is a sync point too: counters and
    // report must agree, under the replica="0" label.
    let mut engine = mk_mock_engine(clusterfusion::util::clock::VirtualClock::shared());
    let obs = Obs::new();
    engine.set_obs(obs.clone(), 0);
    let report =
        loadgen::replay(&mut engine, &load_requests(CRASH_RPS), &svc(), 1_000_000).expect("replay");
    let reg = obs.registry();
    assert_eq!(reg.counter("replay_completed_total"), report.completed as u64);
    assert_eq!(reg.counter("replay_rejected_total"), report.rejected);
    assert_eq!(reg.counter("engine_steps_total{replica=\"0\"}"), report.steps);
    assert_eq!(reg.counter("engine_tokens_out_total{replica=\"0\"}"), report.tokens_out);
    assert_eq!(reg.counter("engine_preemptions_total{replica=\"0\"}"), report.preemptions);
    let h = reg.histogram("request_e2e_ms").expect("e2e histogram exists");
    assert_eq!(h.count(), report.completed as u64);
    // step spans: one per executed step, each annotated with its shape
    let steps = obs
        .events()
        .iter()
        .filter(|e| e.cat == "engine" && e.name == "step")
        .count() as u64;
    assert_eq!(steps, report.steps, "one step span per executed step");
}

// ---------------------------------------------------------------------
// 3. the Chrome export parses back and nests well-formed
// ---------------------------------------------------------------------

#[test]
fn chrome_trace_parses_back_with_well_formed_nesting() {
    let (obs, _) = crash_replay_with_obs();
    let text = obs.chrome_trace();
    let v = Json::parse(&text).expect("trace JSON parses");
    let evs = v.get("traceEvents").expect("traceEvents").as_arr().expect("array");
    assert_eq!(evs.len(), obs.events().len(), "every event renders");
    assert!(!evs.is_empty());

    // Collect spans per (pid, tid) track; instants only need a ph check.
    let mut tracks: BTreeMap<(usize, usize), Vec<(u64, u64)>> = BTreeMap::new();
    for e in evs {
        let ph = e.get("ph").expect("ph").as_str().expect("ph str");
        let ts = e.get("ts").expect("ts").as_usize().expect("ts uint") as u64;
        let pid = e.get("pid").expect("pid").as_usize().expect("pid uint");
        let tid = e.get("tid").expect("tid").as_usize().expect("tid uint");
        match ph {
            "X" => {
                let dur = e.get("dur").expect("dur").as_usize().expect("dur uint") as u64;
                tracks.entry((pid, tid)).or_default().push((ts, ts + dur));
            }
            "i" => assert_eq!(e.get("s").and_then(|s| s.as_str()), Some("p"), "instant scope"),
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(!tracks.is_empty(), "the crash scenario must produce spans");

    // Containment sweep per track: sort by (start asc, end desc) so a
    // parent precedes the children it contains, then walk with a stack.
    // Every span must end within the enclosing open span — Chrome/
    // Perfetto render exactly this nesting.
    for ((pid, tid), mut spans) in tracks {
        spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut stack: Vec<(u64, u64)> = Vec::new();
        for (start, end) in spans {
            assert!(end >= start, "span end precedes start on ({pid},{tid})");
            while let Some(&(_, open_end)) = stack.last() {
                if open_end <= start {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(_, open_end)) = stack.last() {
                assert!(
                    end <= open_end,
                    "span [{start},{end}] escapes its parent (ends {open_end}) on ({pid},{tid})"
                );
            }
            stack.push((start, end));
        }
    }

    // Kernel children tile their step spans: per step track, kernel span
    // time sums to step span time exactly.
    let events = obs.events();
    for pid in [0u64, 1] {
        let step_us: u64 = events
            .iter()
            .filter(|e| e.pid == pid && e.cat == "engine" && e.name == "step")
            .map(|e| e.dur_us())
            .sum();
        let kernel_us: u64 = events
            .iter()
            .filter(|e| e.pid == pid && e.cat == "kernel")
            .map(|e| e.dur_us())
            .sum();
        assert_eq!(kernel_us, step_us, "replica {pid}: kernel spans must tile the steps");
    }
    // No zero-phase leakage: every span event really is a Span.
    assert!(events
        .iter()
        .filter(|e| e.cat == "kernel")
        .all(|e| matches!(e.phase, TracePhase::Span { .. })));
}
