//! Front-door integration tests: the latency-targeted admission layer
//! (`coordinator::admission`) end to end through `Engine` + virtual-clock
//! replay, plus the router regressions this PR fixed.
//!
//! Scenario math matches `integration_load` (see EXPERIMENTS.md §Load
//! saturation): requests are 16 prompt + 8 generated tokens (24-token
//! worst case), prefill chunk 4, service model 200 + 50·decode +
//! 50·prefill µs per step — so a full decode batch of 8 steps in 600 µs
//! and the worst mixed step costs 750 µs. Every pinned number below is a
//! pure function of (rate, TRACE_SEED, SYNTH_SEED, admission knobs) on
//! the virtual clock.

use clusterfusion::coordinator::admission::{AdmissionConfig, SubmitOutcome};
use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::request::{Event, FinishReason, Request};
use clusterfusion::coordinator::router::Router;
use clusterfusion::loadgen::{self, ReplayReport, ServiceModel};
use clusterfusion::util::clock::VirtualClock;
use clusterfusion::workload::{SeqlenDist, Trace};

const N_REQUESTS: usize = 160;
const TRACE_SEED: u64 = 42;
const SYNTH_SEED: u64 = 7;
const OVERLOAD_RPS: f64 = 1500.0;

fn load_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
        vec![1, 2, 4, 8],
    )
}

fn svc() -> ServiceModel {
    ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 }
}

/// The `integration_load` saturation scenario with an explicit front-door
/// config. Fully determined by (rps, admission, the pinned seeds).
fn run_with_admission(rps: f64, admission: AdmissionConfig) -> (ReplayReport, Engine<MockBackend>) {
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4);
    engine.set_admission(admission);
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let rep = loadgen::replay(&mut engine, &requests, &svc(), 1_000_000).expect("replay");
    (rep, engine)
}

// ---------------------------------------------------------------------
// router regressions
// ---------------------------------------------------------------------

#[test]
fn router_routes_past_a_full_queue_on_the_least_total_replica() {
    // The exact scenario from the old bug: replica 0 queued=cap/running=0
    // (total 2), replica 1 queued=0/running=cap+1 (total 3). The buggy
    // route() min'd by total, landed on replica 0, saw its full queue and
    // rejected — with replica 1 wide open.
    let cap = 2;
    let mut router = Router::new(2, cap);
    for id in 0..6u64 {
        assert_eq!(router.route(&Request::new(id, vec![1], 1)).unwrap().replica, id as usize % 2);
        router.on_started(id);
    }
    for id in [0u64, 2, 4] {
        router.on_finished(id);
    }
    router.route(&Request::new(6, vec![1], 1)).unwrap();
    router.route(&Request::new(7, vec![1], 1)).unwrap();
    assert_eq!((router.load(0).queued, router.load(0).running), (cap, 0));
    assert_eq!((router.load(1).queued, router.load(1).running), (0, cap + 1));
    let route = router.route(&Request::new(999, vec![1], 1)).unwrap();
    assert_eq!(route.replica, 1, "headroom on replica 1 must win over the smaller total");
    assert_eq!(router.stats().rejected, 0);
}

#[test]
fn router_double_transitions_never_corrupt_the_load_split() {
    // The old on_started(replica) pattern debug_assert'd then
    // saturating_sub'd: in release builds a double-start drove queued to
    // 0 while running climbed, permanently skewing least-loaded picks.
    let mut router = Router::new(2, 8);
    router.route(&Request::new(1, vec![1; 4], 4)).unwrap();
    router.on_started(1);
    router.on_started(1); // duplicate pickup notification
    router.on_started(77); // pickup for a request never routed
    assert_eq!((router.load(0).queued, router.load(0).running), (0, 1));
    router.on_finished(1);
    router.on_finished(1); // duplicate completion
    assert_eq!((router.load(0).queued, router.load(0).running), (0, 0));
    assert_eq!(router.load(0).tokens, 0, "token footprint fully returned");
    let stats = router.stats();
    assert_eq!(stats.spurious_starts, 2);
    assert_eq!(stats.spurious_finishes, 1);
    // the router still balances correctly afterwards
    assert_eq!(router.route(&Request::new(2, vec![1], 1)).unwrap().replica, 0);
}

#[test]
fn router_token_budget_spreads_by_footprint_not_count() {
    // 3 replicas, 64-token budget each; 24-token requests: two per
    // replica (48), the seventh must wait for a completion.
    let mut router = Router::new(3, 100).with_token_budget(64);
    let req = |id| Request::new(id, vec![1; 16], 8);
    for id in 0..6u64 {
        router.route(&req(id)).unwrap();
    }
    assert!(router.route(&req(6)).is_err(), "all replicas at 48/64: +24 overshoots");
    router.on_finished(0);
    assert_eq!(router.route(&req(6)).unwrap().replica, 0);
    let stats = router.stats();
    assert_eq!((stats.routed, stats.rejected), (7, 1));
}

// ---------------------------------------------------------------------
// engine front door: context-window and SLO rejection
// ---------------------------------------------------------------------

#[test]
fn context_limit_finishes_in_flight_and_rejects_at_submit() {
    // Satellite fix, both halves. (1) submit: a request that can never
    // fit max_seq is refused up front with an event. (2) in-flight: a
    // sequence that reaches max_seq anyway (injected past the front
    // door, as a preemption-requeue could) finishes with a length-capped
    // stop instead of stalling the engine forever.
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    assert_eq!(
        engine.submit(Request::new(1, vec![1; 32], 40)),
        SubmitOutcome::RejectedTooLong,
        "32 + 40 > max_seq 64"
    );
    assert!(engine.idle());
    let events = engine.take_events();
    assert!(
        matches!(
            events.as_slice(),
            [Event::Finished { id: 1, reason: FinishReason::Rejected, .. }]
        ),
        "{events:?}"
    );
    // boundary: exactly max_seq is admitted and completes
    assert!(engine.submit(Request::new(2, vec![1; 32], 32)).is_queued());
    engine.run_to_completion(1_000).unwrap();
    // inject an over-window request straight into the batcher
    engine.batcher.submit(Request::new(3, vec![1; 32], 40), 0);
    engine.run_to_completion(1_000).unwrap();
    let reasons: Vec<(u64, FinishReason)> = engine
        .take_events()
        .iter()
        .filter_map(|ev| match ev {
            Event::Finished { id, reason, .. } => Some((*id, *reason)),
            _ => None,
        })
        .collect();
    assert!(reasons.contains(&(2, FinishReason::Length)));
    assert!(
        reasons.contains(&(3, FinishReason::CacheFull)),
        "in-flight context-limit must finish, not stall: {reasons:?}"
    );
    assert_eq!(engine.rejected_too_long, 1);
    assert_eq!(engine.pool.used_pages(), 0);
}

#[test]
fn slo_overload_rejects_the_tail_and_protects_admitted_ttft() {
    // 1500 rps is ~2.9x past the knee: unbounded, p99 TTFT explodes to
    // ~190 ms. A 25 ms TTFT SLO sheds the excess at submit instead.
    let slo = AdmissionConfig { slo_ttft_us: 25_000, service: svc(), ..AdmissionConfig::off() };
    let (rep, engine) = run_with_admission(OVERLOAD_RPS, slo);
    assert_eq!(rep.completed + rep.rejected as usize, N_REQUESTS);
    assert!(rep.rejected > 0, "overload must shed load");
    assert_eq!(engine.rejected_slo, rep.rejected, "all rejections are SLO rejections");
    assert_eq!(engine.rejected_too_long, 0);
    // every admitted request's TTFT meets the target the projection
    // promised (the projection prices the worst mixed step, so it is
    // conservative)
    for t in engine.timings() {
        assert!(t.ttft <= 0.025 + 1e-9, "req {} ttft {} breached the SLO", t.id, t.ttft);
    }
    assert!(rep.percentiles.ttft.p99 <= 0.025 + 1e-9, "{}", rep.percentiles.ttft.p99);
}

#[test]
fn tpot_cap_and_token_budget_bind_identically_here() {
    // Two different knobs, same effective concurrency on this workload:
    // a 500 µs TPOT target caps decode width at 2 (step_us(2,4) = 500),
    // and a 48-token budget fits exactly two 24-token requests. The
    // whole virtual-clock trajectory must agree byte for byte.
    let tpot = AdmissionConfig { slo_tpot_us: 500, service: svc(), ..AdmissionConfig::off() };
    let budget = AdmissionConfig { max_batch_total_tokens: 48, ..AdmissionConfig::off() };
    let (rep_tpot, eng_tpot) = run_with_admission(OVERLOAD_RPS, tpot);
    let (rep_budget, eng_budget) = run_with_admission(OVERLOAD_RPS, budget);
    assert_eq!(rep_tpot.render(), rep_budget.render());
    assert_eq!(rep_tpot.completed, N_REQUESTS, "capped concurrency still drains everything");
    assert_eq!(rep_tpot.rejected, 0, "neither knob rejects — they defer");
    // narrow batches decode faster per token than the full-width
    // baseline's worst mixed step (750 µs)
    assert!(rep_tpot.percentiles.tpot.p99 < 0.00075, "{}", rep_tpot.percentiles.tpot.p99);
    assert_eq!(eng_tpot.steps, eng_budget.steps);
    assert_eq!(eng_tpot.growth_deferrals, 0, "slot caps are not growth deferrals");
    assert_eq!(eng_budget.growth_deferrals, 0);
}

#[test]
fn growth_gate_defers_at_overload_but_completes_everything() {
    let gate = AdmissionConfig {
        waiting_served_ratio: 2.0,
        max_waiting_steps: 16,
        ..AdmissionConfig::off()
    };
    let (rep, engine) = run_with_admission(OVERLOAD_RPS, gate);
    assert_eq!(rep.completed, N_REQUESTS, "the gate defers, it never drops");
    assert_eq!(rep.rejected, 0);
    assert!(engine.growth_deferrals > 0, "overload must trip the ratio gate");
    // max_waiting_steps bounds every deferral streak, so the queue can
    // never be starved longer than 16 steps
    assert!(
        engine.growth_deferrals < rep.steps,
        "deferrals {} must not dominate {} steps",
        engine.growth_deferrals,
        rep.steps
    );
}

#[test]
fn front_door_replay_is_byte_deterministic() {
    // DESIGN.md §4 extended to admission: every front-door decision is a
    // pure function of engine-visible state, so two identically-seeded
    // runs — rejections included — render byte-identically.
    let cfg = || AdmissionConfig {
        slo_ttft_us: 25_000,
        slo_tpot_us: 750,
        waiting_served_ratio: 1.5,
        max_waiting_steps: 16,
        max_batch_total_tokens: 120,
        service: svc(),
    };
    let (a, ea) = run_with_admission(OVERLOAD_RPS, cfg());
    let (b, eb) = run_with_admission(OVERLOAD_RPS, cfg());
    assert_eq!(a.render(), b.render());
    assert_eq!(ea.rejected_slo, eb.rejected_slo);
    assert_eq!(ea.growth_deferrals, eb.growth_deferrals);
    assert!(a.rejected > 0, "the combined config must shed at 1500 rps");
}

#[test]
fn off_config_replays_identically_to_no_front_door() {
    // AdmissionConfig::off() must be byte-invisible: the same scenario
    // with and without set_admission renders identically.
    let (with_off, _) = run_with_admission(OVERLOAD_RPS, AdmissionConfig::off());
    let mut engine = Engine::with_clock(load_mock(), 40, 4, 0.5, VirtualClock::shared());
    engine.set_prefill_chunk(4);
    let trace =
        Trace::poisson(N_REQUESTS, OVERLOAD_RPS, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    let requests = loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED);
    let bare = loadgen::replay(&mut engine, &requests, &svc(), 1_000_000).expect("replay");
    assert_eq!(with_off.render(), bare.render());
    assert_eq!(with_off.rejected, 0);
}
