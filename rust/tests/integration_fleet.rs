//! Fleet integration tests: replicated serving behind the router with
//! deterministic fault injection, failover, and deadlines
//! (`coordinator::fleet`).
//!
//! Scenario math matches `integration_router` / `integration_load`:
//! requests are 16 prompt + 8 generated tokens (24-token worst case),
//! prefill chunk 4, service model 200 + 50·decode + 50·prefill µs per
//! step, pool 40 pages × 4 tokens per replica. Every pinned number is a
//! pure function of (rate, TRACE_SEED, SYNTH_SEED, FaultPlan, knobs) on
//! the shared virtual clock — the suite's core assertion is that fleet
//! replays are byte-identical across runs, faults included.

use std::collections::BTreeMap;

use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::fleet::{FaultPlan, Fleet, FleetOptions, FleetReport};
use clusterfusion::coordinator::functional_backend::FunctionalBackend;
use clusterfusion::coordinator::request::Request;
use clusterfusion::coordinator::router::{ReplicaHealth, Router};
use clusterfusion::loadgen::{self, ServiceModel};
use clusterfusion::util::clock::{SharedClock, VirtualClock};
use clusterfusion::util::rng::Rng;
use clusterfusion::workload::{SeqlenDist, Trace};

const N_REQUESTS: usize = 160;
const TRACE_SEED: u64 = 42;
const SYNTH_SEED: u64 = 7;
/// The pinned tier-1 rate: saturating but completable on two replicas.
const CRASH_RPS: f64 = 450.0;

fn load_mock() -> MockBackend {
    MockBackend::new(
        ModelGeom { vocab: 64, n_layers: 2, row_elems: 4, planes: 2, max_seq: 64 },
        vec![1, 2, 4, 8],
    )
}

fn svc() -> ServiceModel {
    ServiceModel { step_base_us: 200, step_per_seq_us: 50, step_prefill_token_us: 50 }
}

fn mk_mock_engine(clock: SharedClock) -> Engine<MockBackend> {
    let mut e = Engine::with_clock(load_mock(), 40, 4, 0.5, clock);
    e.set_prefill_chunk(4);
    e
}

fn load_requests(rps: f64) -> Vec<Request> {
    let trace = Trace::poisson(N_REQUESTS, rps, SeqlenDist::Fixed(24), (8, 8), 64, TRACE_SEED);
    loadgen::synthesize_requests(&trace, 64, 16, 8, SYNTH_SEED)
}

fn assert_router_protocol_clean(report: &FleetReport) {
    let s = report.router_stats;
    assert_eq!(
        (s.spurious_starts, s.spurious_finishes, s.spurious_fails, s.spurious_routes),
        (0, 0, 0, 0),
        "the fleet must drive the router strictly in-protocol: {s:?}"
    );
}

// ---------------------------------------------------------------------
// inertness: a fleet of one with no faults IS the single-engine path
// ---------------------------------------------------------------------

#[test]
fn fleet_of_one_with_no_faults_matches_the_single_engine_path() {
    // Acceptance gate: with no FaultPlan configured, the N=1 fleet must
    // render byte-identically to `loadgen::replay` on an identically
    // configured engine — the fleet layer is provably inert when off.
    let requests = load_requests(CRASH_RPS);
    let mut bare = mk_mock_engine(VirtualClock::shared());
    let bare_report = loadgen::replay(&mut bare, &requests, &svc(), 1_000_000).expect("replay");

    let mut fleet = Fleet::build(1, FaultPlan::none(), FleetOptions::default(), mk_mock_engine);
    let report = fleet.replay(&requests, &svc(), 1_000_000).expect("fleet replay");

    assert_eq!(
        report.replicas[0].render(),
        bare_report.render(),
        "fleet-of-one must be indistinguishable from the bare engine"
    );
    assert_eq!(report.routed, N_REQUESTS as u64);
    assert_eq!(report.router_rejected, 0);
    assert_eq!((report.retries, report.evacuated), (0, 0));
    assert!(report.failed.is_empty());
    assert!(report.crashed.is_empty());
    assert_router_protocol_clean(&report);
}

// ---------------------------------------------------------------------
// determinism: byte-identical reports across runs, faults included
// ---------------------------------------------------------------------

fn mock_fleet_render(replicas: usize, plan: &str, opts: FleetOptions, rps: f64) -> String {
    let plan =
        if plan.is_empty() { FaultPlan::none() } else { FaultPlan::parse(plan).expect("plan") };
    let mut fleet = Fleet::build(replicas, plan, opts, mk_mock_engine);
    fleet.replay(&load_requests(rps), &svc(), 1_000_000).expect("fleet replay").render()
}

#[test]
fn fleet_reports_are_byte_identical_across_runs() {
    // Replicas {1, 2, 4} × {no plan, a crash, a detectable stall}: two
    // independently constructed fleets must produce the same bytes.
    let opts = FleetOptions { stall_threshold_us: 2_000, ..FleetOptions::default() };
    for replicas in [1usize, 2, 4] {
        for plan in ["", "crash:0@120000", "stall:0@80000+40000"] {
            let a = mock_fleet_render(replicas, plan, opts, CRASH_RPS);
            let b = mock_fleet_render(replicas, plan, opts, CRASH_RPS);
            assert_eq!(a, b, "replicas={replicas} plan={plan:?} must replay byte-identically");
        }
    }
}

#[test]
fn seeded_fault_plans_replay_byte_identically() {
    // The seeded-plan path (what `--fault-plan seed:N` style experiments
    // use) composes arbitrary faults; determinism must survive them too.
    for seed in [1u64, 9, 23] {
        let plan = FaultPlan::seeded(seed, 4, 300_000);
        let opts = FleetOptions { stall_threshold_us: 2_000, ..FleetOptions::default() };
        let spec = plan.render();
        let run = || mock_fleet_render(4, &spec, opts, CRASH_RPS);
        assert_eq!(run(), run(), "seed {seed} plan {spec:?}");
    }
}

// ---------------------------------------------------------------------
// the pinned failover scenario: crash mid-trace, zero lost requests
// ---------------------------------------------------------------------

#[test]
fn crash_mid_trace_at_450_rps_loses_no_admitted_requests() {
    // Tier-1 acceptance scenario: 450 rps over two replicas, replica 0
    // crashes ~120 ms into the ~355 ms trace (a pure function of seeds
    // 42/7, chosen so the crash provably lands with work in flight).
    // Every admitted request must either complete (possibly after
    // failover recompute) or be explicitly rejected — none may vanish —
    // and the whole report must be byte-stable.
    let run = || {
        let plan = FaultPlan::parse("crash:0@120000").expect("plan");
        let mut fleet = Fleet::build(2, plan, FleetOptions::default(), mk_mock_engine);
        fleet.replay(&load_requests(CRASH_RPS), &svc(), 1_000_000).expect("fleet replay")
    };
    let report = run();

    assert_eq!(report.crashed, vec![0], "replica 0 crashes exactly once");
    assert!(report.evacuated >= 1, "a 120 ms crash at 450 rps must land mid-flight");
    assert!(report.retries >= report.evacuated, "every evacuee consumed a retry");
    assert!(
        report.failed.is_empty(),
        "failover must not exhaust retries with a healthy survivor: {:?}",
        report.failed
    );

    // global accounting identity: every submitted request is exactly one
    // of {completed, failed, engine-rejected, router-rejected}
    let accounted = report.completed() as u64
        + report.failed.len() as u64
        + report.rejected()
        + report.router_rejected;
    assert_eq!(accounted, N_REQUESTS as u64, "a request was lost or double-counted");
    // stronger, for this scenario: queue caps are generous and requests
    // fit the context window, so everything completes
    assert_eq!(report.completed(), N_REQUESTS, "zero lost admitted requests");
    assert_eq!(report.replicas[1].completed + report.replicas[0].completed, N_REQUESTS);
    assert!(
        report.replicas[1].completed > report.replicas[0].completed,
        "the survivor finishes the evacuated majority"
    );
    assert_router_protocol_clean(&report);

    assert_eq!(report.render(), run().render(), "crash replay must be byte-identical");
}

// ---------------------------------------------------------------------
// deadlines through the fleet: distinct from other rejections
// ---------------------------------------------------------------------

#[test]
fn fleet_enforces_deadlines_distinctly_from_other_rejections() {
    // One replica, slow service (1 ms base step). Request 1's deadline
    // passes at a step boundary after admission (expiry, timing kept);
    // request 2 arrives with its deadline already in the past (front-door
    // rejection, no timing). The two paths must stay distinguishable in
    // the fleet report.
    let slow = ServiceModel { step_base_us: 1_000, step_per_seq_us: 50, step_prefill_token_us: 50 };
    let run = || {
        let mut requests = vec![
            Request::new(0, vec![1; 8], 20),
            Request::new(1, vec![2; 8], 4).with_deadline_us(2_000),
            Request::new(2, vec![3; 8], 4).with_deadline_us(100),
        ];
        requests[2].arrival_us = 5_000;
        let mut fleet = Fleet::build(1, FaultPlan::none(), FleetOptions::default(), mk_mock_engine);
        fleet.replay(&requests, &slow, 100_000).expect("fleet replay")
    };
    let report = run();
    assert_eq!(report.routed, 3, "the router accepted all three");
    assert_eq!(report.deadline_expired, 1, "request 1 expires after admission");
    assert_eq!(report.rejected(), 1, "request 2 is refused at the front door");
    assert_eq!(report.completed(), 2, "request 0 finishes; request 1 keeps its timing");
    assert!(report.failed.is_empty());
    assert_router_protocol_clean(&report);
    assert_eq!(report.render(), run().render());
}

// ---------------------------------------------------------------------
// real numerics: a functional-backend fleet is pool-width invariant
// ---------------------------------------------------------------------

fn functional_fleet_render(threads: usize) -> String {
    let mut requests: Vec<Request> = (0..10u64)
        .map(|i| Request::new(i, vec![3 + (i as i32 % 7); 6], 5))
        .collect();
    for (i, r) in requests.iter_mut().enumerate() {
        r.arrival_us = i as u64 * 2_000;
    }
    let mut fleet = Fleet::build(2, FaultPlan::none(), FleetOptions::default(), |clock| {
        let backend = FunctionalBackend::from_model_name_on("micro-llama", 42, 2, threads)
            .expect("micro-llama materializes");
        let mut e = Engine::with_clock(backend, 64, 8, 1.0, clock);
        e.set_prefill_chunk(4);
        e
    });
    fleet.replay(&requests, &svc(), 100_000).expect("fleet replay").render()
}

#[test]
fn functional_fleet_renders_identically_across_host_pools() {
    // Micro-llama on 2 replicas: the worker-pool width (1 vs 4 host
    // threads) is an execution detail and must not leak into the report —
    // same tokens, same timings, same bytes.
    let serial = functional_fleet_render(1);
    assert_eq!(serial, functional_fleet_render(4), "pool width must be report-invariant");
    assert_eq!(serial, functional_fleet_render(1), "and run-to-run stable");
}

// ---------------------------------------------------------------------
// satellite: the router's token budget cannot leak — property test
// ---------------------------------------------------------------------

#[test]
fn router_token_budget_never_leaks_under_random_interleavings() {
    // Drive route / on_started / on_failed / on_finished in random order,
    // including spurious transitions (unknown ids, double finishes) and
    // re-routes of still-open ids (a retry racing its failure
    // notification), plus health flips. Invariant after EVERY operation:
    // the router's aggregate queued/running/token counters equal the
    // model's open set exactly; at quiescence every replica is zero.
    const REPLICAS: usize = 3;
    for seed in 0..12u64 {
        let mut router = Router::new(REPLICAS, 4).with_token_budget(64);
        let mut rng = Rng::seed_from_u64(seed);
        // model of what *should* be inflight: id -> worst-case tokens
        let mut open: BTreeMap<u64, usize> = BTreeMap::new();
        let mut next_id = 0u64;
        let pick = |open: &BTreeMap<u64, usize>, rng: &mut Rng| -> Option<u64> {
            if open.is_empty() {
                None
            } else {
                open.keys().nth(rng.below(open.len())).copied()
            }
        };
        for _ in 0..400 {
            match rng.below(8) {
                0 | 1 | 2 => {
                    // fresh route (may be rejected: budget/queue/health)
                    let req = Request::new(next_id, vec![1; 1 + rng.below(12)], 4);
                    if router.route(&req).is_ok() {
                        open.insert(next_id, req.max_total_len());
                    }
                    next_id += 1;
                }
                3 => {
                    // re-route a still-open id: the stale ledger must be
                    // released, never doubled
                    if let Some(id) = pick(&open, &mut rng) {
                        let req = Request::new(id, vec![1; 1 + rng.below(12)], 4);
                        if router.route(&req).is_ok() {
                            open.insert(id, req.max_total_len());
                        }
                    }
                }
                4 => {
                    // start an open id (phase move) or an unknown one
                    // (spurious no-op)
                    let id = if rng.bool() {
                        pick(&open, &mut rng).unwrap_or(u64::MAX)
                    } else {
                        1_000_000 + rng.below(8) as u64
                    };
                    router.on_started(id);
                }
                5 => {
                    if rng.bool() {
                        if let Some(id) = pick(&open, &mut rng) {
                            router.on_finished(id);
                            open.remove(&id);
                        }
                    } else {
                        router.on_finished(1_000_000 + rng.below(8) as u64);
                    }
                }
                6 => {
                    if rng.bool() {
                        if let Some(id) = pick(&open, &mut rng) {
                            router.on_failed(id);
                            open.remove(&id);
                        }
                    } else {
                        router.on_failed(1_000_000 + rng.below(8) as u64);
                    }
                }
                _ => {
                    // health flips gate routing but must never touch the
                    // ledger
                    let h = match rng.below(3) {
                        0 => ReplicaHealth::Healthy,
                        1 => ReplicaHealth::Unhealthy,
                        _ => ReplicaHealth::Draining,
                    };
                    router.set_health(rng.below(REPLICAS), h);
                }
            }
            let tokens: usize = (0..REPLICAS).map(|i| router.load(i).tokens).sum();
            let total: usize = (0..REPLICAS).map(|i| router.load(i).total()).sum();
            assert_eq!(tokens, open.values().sum::<usize>(), "token drift (seed {seed})");
            assert_eq!(total, open.len(), "slot drift (seed {seed})");
        }
        // quiesce: close every open id through either exit path
        let ids: Vec<u64> = open.keys().copied().collect();
        for id in ids {
            if rng.bool() {
                router.on_finished(id);
            } else {
                router.on_failed(id);
            }
        }
        for i in 0..REPLICAS {
            let l = router.load(i);
            assert_eq!(
                (l.queued, l.running, l.tokens),
                (0, 0, 0),
                "replica {i} leaked counters at quiescence (seed {seed})"
            );
        }
    }
}
