//! End-to-end smoke tests over the public API with the mock backend:
//! the full coordinator lifecycle (admit → decode steps → finish reason →
//! metrics) plus a snapshot of the quickstart example's deterministic
//! mock-backend output, so `cargo test` guards what
//! `cargo run --example quickstart` prints on a fresh checkout.

use clusterfusion::coordinator::engine::{Engine, MockBackend, ModelGeom};
use clusterfusion::coordinator::request::{Event, FinishReason, Request};

/// The full admit → prefill → decode → finish → metrics lifecycle,
/// observed step by step from outside the crate.
#[test]
fn lifecycle_smoke_admit_decode_finish_metrics() {
    let mut engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);

    // Nothing queued: a step is a no-op and reports it.
    assert!(engine.idle());
    assert!(!engine.step().unwrap(), "idle engine must do nothing");

    // Admission happens inside the first step after submit.
    engine.submit(Request::new(42, vec![9, 9, 9], 2));
    assert!(!engine.idle());
    assert!(engine.step().unwrap());
    assert_eq!(engine.pool.seq_len(42), Some(3), "one-shot prefill fed the whole prompt");

    // Drive to completion; the prefill step already emitted the first
    // token, so gen(2) needs just one more decode step: 2 total.
    engine.run_to_completion(16).unwrap();
    assert_eq!(engine.steps, 2);
    assert_eq!(engine.tokens_out, 2);

    // Event stream shape: FirstToken, Token, Finished(Length).
    let events = engine.take_events();
    assert!(matches!(events.first(), Some(Event::FirstToken { id: 42, .. })));
    match events.last() {
        Some(Event::Finished { id: 42, reason, generated, .. }) => {
            assert_eq!(*reason, FinishReason::Length);
            assert_eq!(generated.len(), 2);
        }
        other => panic!("expected Finished, got {other:?}"),
    }

    // Metrics recorded, resources returned.
    let timings = engine.timings();
    assert_eq!(timings.len(), 1);
    assert_eq!(timings[0].id, 42);
    assert_eq!(timings[0].prompt_len, 3);
    assert_eq!(timings[0].generated, 2);
    assert!(timings[0].total >= timings[0].ttft && timings[0].ttft >= 0.0);
    assert_eq!(engine.pool.used_pages(), 0, "pages freed at finish");
    assert!(engine.idle());
}

/// Snapshot of the quickstart example's mock path: prompt [3, 5] on
/// `MockBackend::tiny()` must always generate [6, 8, 11] and finish with
/// Length. If this changes, update examples/quickstart.rs alongside.
#[test]
fn quickstart_mock_snapshot() {
    let mut engine = Engine::new(MockBackend::tiny(), 64, 4, 1.0);
    engine.submit(Request::new(1, vec![3, 5], 3));
    engine.run_to_completion(100).unwrap();
    let events = engine.take_events();
    let tokens: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(tokens, vec![6, 8, 11], "quickstart output drifted");
    assert!(matches!(
        events.last(),
        Some(Event::Finished { reason: FinishReason::Length, .. })
    ));
    // one-shot prefill (emits the first token) + two decode steps
    assert_eq!(engine.steps, 3);
    assert_eq!(engine.tokens_out, 3);
}

/// A custom-geometry mock exercised through the same public API, checking
/// that KV rows written by the backend land in the pool where the engine
/// says they should (plane/layer/position addressing).
#[test]
fn kv_rows_land_where_addressed() {
    let geom = ModelGeom { vocab: 64, n_layers: 3, row_elems: 4, planes: 2, max_seq: 32 };
    let mut engine = Engine::new(MockBackend::new(geom, vec![1, 2]), 32, 4, 1.0);
    engine.submit(Request::new(5, vec![11, 13], 30));
    for _ in 0..3 {
        engine.step().unwrap();
    }
    // 4 tokens appended: the one-shot prefill step fed prompt 11 @ pos 0
    // and 13 @ pos 1, then two decode steps appended the generated
    // tokens. MockBackend encodes (token, pos, plane) per row.
    assert_eq!(engine.pool.seq_len(5), Some(4));
    let row = engine.pool.peek(5, 1, 2, 1).unwrap();
    assert_eq!(row[0], 13.0, "token at pos 1");
    assert_eq!(row[1], 1.0, "pos encoded");
    assert_eq!(row[2], 1.0, "plane encoded");
    // every layer got the same row for this (token, plane)
    for layer in 0..3 {
        assert_eq!(engine.pool.peek(5, 1, layer, 1).unwrap(), row);
    }
}
