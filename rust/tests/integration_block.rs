//! Full-block subsystem suite (tier-1).
//!
//! * **Scalar differential** — the functional block pipeline
//!   (`clustersim::block::BlockModel`, which composes the fused
//!   attention dataflows with the linalg row primitives) must match a
//!   FROZEN plain-loop scalar reference of the whole transformer block
//!   (RMSNorm → QKV → rotary → attention → output projection → residual
//!   → SwiGLU MLP → residual → tied logits head) to fp32 tolerance,
//!   multi-layer and multi-step, across MHA + MLA geometries and cluster
//!   sizes. The reference below is self-contained on purpose: if these
//!   tests trip, the pipeline changed semantics — fix the pipeline, not
//!   the reference.
//! * **Greedy determinism** — the same seed must produce byte-identical
//!   token streams across two independent engine runs on the virtual
//!   clock.
//! * **Fusion-scope properties** — the three cost scopes agree on FLOPs
//!   and are monotone in HBM traffic and kernel launches at *every*
//!   cluster size; latency obeys full ≤ attn ≤ isolated at the tuned
//!   cluster size of every tested geometry.
//! * **Replay acceptance** — `loadgen::replay` drives an
//!   `Engine<FunctionalBackend>` through a Poisson trace on the virtual
//!   clock, billing `ServiceModel::from_block` costs, and renders a
//!   byte-stable percentile report.

use clusterfusion::clustersim::block::{
    self, BlockModel, BlockProblem, FusionScope, EPS, ROPE_BASE,
};
use clusterfusion::clustersim::collective::Transport;
use clusterfusion::clustersim::dataflow::CostEnv;
use clusterfusion::clustersim::{Hardware, Noc};
use clusterfusion::coordinator::engine::Engine;
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::FunctionalBackend;
use clusterfusion::loadgen::{self, ServiceModel};
use clusterfusion::models::{AttnKind, AttnWeights, MaterializedWeights, ModelConfig};
use clusterfusion::util::clock::VirtualClock;
use clusterfusion::workload::{SeqlenDist, Trace};

// ---------------------------------------------------------------------------
// Frozen scalar reference (plain loops; no linalg, no dataflows).
// ---------------------------------------------------------------------------

fn ref_rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let mut ss = 0f32;
    for v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / x.len() as f32 + EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// `y[col] += Σ_i x[i] · w[i*n_out + col]`, one slot.
fn ref_gemm(x: &[f32], w: &[f32], n_in: usize, n_out: usize, y: &mut [f32]) {
    for col in 0..n_out {
        let mut acc = 0f32;
        for i in 0..n_in {
            acc += x[i] * w[i * n_out + col];
        }
        y[col] += acc;
    }
}

fn ref_rope(row: &mut [f32], pos: usize) {
    let half = row.len() / 2;
    for i in 0..half {
        let theta = pos as f32 * ROPE_BASE.powf(-(i as f32) / half as f32);
        let (sin, cos) = theta.sin_cos();
        let (a, b) = (row[i], row[half + i]);
        row[i] = a * cos - b * sin;
        row[half + i] = a * sin + b * cos;
    }
}

/// Softmax attention of one head over `n` cached rows plus the self row.
/// `cache_row(t)` yields the `dh`-sized key/value rows.
fn ref_attend(
    q: &[f32],
    n: usize,
    scale: f32,
    key_at: impl Fn(usize) -> Vec<f32>,
    val_at: impl Fn(usize) -> Vec<f32>,
    k_self: &[f32],
    v_self: &[f32],
    out: &mut [f32],
) {
    let dot = |a: &[f32], b: &[f32]| -> f32 {
        let mut s = 0f32;
        for i in 0..a.len() {
            s += a[i] * b[i];
        }
        s
    };
    let mut scores: Vec<f32> = (0..n).map(|t| dot(q, &key_at(t)) * scale).collect();
    scores.push(dot(q, k_self) * scale);
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut l = 0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        l += *s;
    }
    for (t, &p) in scores[..n].iter().enumerate() {
        let v = val_at(t);
        for (o, vv) in out.iter_mut().zip(&v) {
            *o += p * vv;
        }
    }
    for (o, vv) in out.iter_mut().zip(v_self) {
        *o += scores[n] * vv;
    }
    for o in out.iter_mut() {
        *o /= l;
    }
}

/// One full-block decode step of the frozen scalar model. Layouts match
/// the serving engine: `caches[plane]` is dense `(L, B, S, re)`; returns
/// `(logits (B, vocab), new_rows per plane (L, B, re))`.
#[allow(clippy::too_many_arguments)]
fn ref_decode_step(
    w: &MaterializedWeights,
    tokens: &[i32],
    pos: &[usize],
    caches: &[Vec<f32>],
    b: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let cfg = &w.config;
    let (d, f, v, s) = (cfg.d_model, cfg.ffn_dim, cfg.vocab, cfg.max_seq);
    let (nh, dh, nl) = (cfg.n_heads, cfg.head_dim, cfg.n_layers);
    let h = nh * dh;
    let (re, planes) = match cfg.attn {
        AttnKind::Mha => (h, 2),
        AttnKind::Mla => (cfg.kv_lora_rank, 1),
    };
    let plane_len = b * s * re;
    let mut logits = vec![0f32; b * v];
    let mut new_rows = vec![vec![0f32; nl * b * re]; planes];

    for bi in 0..b {
        let tok = tokens[bi].rem_euclid(v as i32) as usize;
        let mut hid: Vec<f32> = w.embedding[tok * d..(tok + 1) * d].to_vec();
        let mut x = vec![0f32; d];
        for (l, lw) in w.layers.iter().enumerate() {
            ref_rmsnorm(&hid, &lw.attn_norm, &mut x);
            let mut attn_out = vec![0f32; d];
            match &lw.attn {
                AttnWeights::Mha { wq, wk, wv, wo } => {
                    let mut q = vec![0f32; h];
                    let mut kn = vec![0f32; h];
                    let mut vn = vec![0f32; h];
                    ref_gemm(&x, wq, d, h, &mut q);
                    ref_gemm(&x, wk, d, h, &mut kn);
                    ref_gemm(&x, wv, d, h, &mut vn);
                    for head in 0..nh {
                        ref_rope(&mut q[head * dh..(head + 1) * dh], pos[bi]);
                        ref_rope(&mut kn[head * dh..(head + 1) * dh], pos[bi]);
                    }
                    let scale = 1.0 / (dh as f32).sqrt();
                    for head in 0..nh {
                        let row = |plane: usize, t: usize| -> Vec<f32> {
                            let base = l * plane_len + (bi * s + t) * h + head * dh;
                            caches[plane][base..base + dh].to_vec()
                        };
                        let mut acc = vec![0f32; dh];
                        ref_attend(
                            &q[head * dh..(head + 1) * dh],
                            pos[bi],
                            scale,
                            |t| row(0, t),
                            |t| row(1, t),
                            &kn[head * dh..(head + 1) * dh],
                            &vn[head * dh..(head + 1) * dh],
                            &mut acc,
                        );
                        // out += acc @ wo[head*dh.., :]
                        for col in 0..d {
                            let mut a = 0f32;
                            for i in 0..dh {
                                a += acc[i] * wo[(head * dh + i) * d + col];
                            }
                            attn_out[col] += a;
                        }
                    }
                    new_rows[0][(l * b + bi) * re..(l * b + bi + 1) * re].copy_from_slice(&kn);
                    new_rows[1][(l * b + bi) * re..(l * b + bi + 1) * re].copy_from_slice(&vn);
                }
                AttnWeights::Mla { wq, wkv, w_down, wo } => {
                    let lr = cfg.kv_lora_rank;
                    let mut q = vec![0f32; nh * lr];
                    let mut kvn = vec![0f32; lr];
                    ref_gemm(&x, wq, d, nh * lr, &mut q);
                    ref_gemm(&x, wkv, d, lr, &mut kvn);
                    let scale = 1.0 / (lr as f32).sqrt();
                    for head in 0..nh {
                        let row = |t: usize| -> Vec<f32> {
                            let base = l * plane_len + (bi * s + t) * lr;
                            caches[0][base..base + lr].to_vec()
                        };
                        let mut attn = vec![0f32; lr];
                        ref_attend(
                            &q[head * lr..(head + 1) * lr],
                            pos[bi],
                            scale,
                            &row,
                            &row,
                            &kvn,
                            &kvn,
                            &mut attn,
                        );
                        let mut z = vec![0f32; dh];
                        ref_gemm(
                            &attn,
                            &w_down[head * lr * dh..(head + 1) * lr * dh],
                            lr,
                            dh,
                            &mut z,
                        );
                        for col in 0..d {
                            let mut a = 0f32;
                            for i in 0..dh {
                                a += z[i] * wo[(head * dh + i) * d + col];
                            }
                            attn_out[col] += a;
                        }
                    }
                    new_rows[0][(l * b + bi) * re..(l * b + bi + 1) * re].copy_from_slice(&kvn);
                }
            }
            for i in 0..d {
                hid[i] += attn_out[i];
            }
            // SwiGLU MLP
            ref_rmsnorm(&hid, &lw.mlp_norm, &mut x);
            let mut gate = vec![0f32; f];
            let mut up = vec![0f32; f];
            ref_gemm(&x, &lw.w_gate, d, f, &mut gate);
            ref_gemm(&x, &lw.w_up, d, f, &mut up);
            let mut act = vec![0f32; f];
            for i in 0..f {
                act[i] = gate[i] / (1.0 + (-gate[i]).exp()) * up[i];
            }
            let mut down = vec![0f32; d];
            ref_gemm(&act, &lw.w_down, f, d, &mut down);
            for i in 0..d {
                hid[i] += down[i];
            }
        }
        ref_rmsnorm(&hid, &w.final_norm, &mut x);
        for t in 0..v {
            let mut a = 0f32;
            for i in 0..d {
                a += x[i] * w.embedding[t * d + i];
            }
            logits[bi * v + t] = a;
        }
    }
    (logits, new_rows)
}

// ---------------------------------------------------------------------------
// Differential harness
// ---------------------------------------------------------------------------

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        assert!((x - y).abs() / denom < tol, "{what}[{i}]: {x} vs {y} (tol {tol})");
    }
}

/// Append one step's new rows into a dense `(L, B, S, re)` plane set at
/// position `t` (what `KvPool::append` + `gather_batch_into` produce).
fn append_rows(
    caches: &mut [Vec<f32>],
    rows: &[Vec<f32>],
    cfg: &ModelConfig,
    re: usize,
    b: usize,
    t: usize,
) {
    let s = cfg.max_seq;
    for (plane, cache) in caches.iter_mut().enumerate() {
        for l in 0..cfg.n_layers {
            for bi in 0..b {
                let src = (l * b + bi) * re;
                let dst = ((l * b + bi) * s + t) * re;
                cache[dst..dst + re].copy_from_slice(&rows[plane][src..src + re]);
            }
        }
    }
}

/// Drive `steps` greedy decode steps of the functional pipeline against
/// the frozen scalar reference, each maintaining its own cache, and
/// compare logits every step. Returns the functional greedy stream.
fn differential_decode(cfg: &ModelConfig, seed: u64, cluster: usize, steps: usize) -> Vec<usize> {
    let weights = MaterializedWeights::materialize(cfg, seed);
    // the scalar reference below needs the raw tensors too: clone for
    // the packed model (BlockModel::new moves its input by design)
    let model = BlockModel::new(weights.clone(), cluster, Transport::Dsmem);
    let (b, re, planes) = (2usize, model.row_elems(), model.planes());
    let s = cfg.max_seq;
    let plane_elems = cfg.n_layers * b * s * re;
    let mut fun_cache = vec![vec![0f32; plane_elems]; planes];
    let mut ref_cache = vec![vec![0f32; plane_elems]; planes];
    // two slots decode different prompts in one padded batch
    let mut tokens = [3i32, 7i32];
    let mut stream = Vec::new();
    for t in 0..steps {
        let pos = [t as i32, t as i32];
        let pos_us = [t, t];
        let (logits, rows) = model.decode_step(&tokens, &pos, &fun_cache, b);
        let (ref_logits, ref_rows) = ref_decode_step(&weights, &tokens, &pos_us, &ref_cache, b);
        assert_close(
            &logits,
            &ref_logits,
            2e-3,
            &format!("{} n={cluster} step {t} logits", cfg.name),
        );
        append_rows(&mut fun_cache, &rows, cfg, re, b, t);
        append_rows(&mut ref_cache, &ref_rows, cfg, re, b, t);
        // greedy-continue both slots from the functional argmax; the
        // reference must agree wherever its top-2 margin is decisive
        let v = cfg.vocab;
        for bi in 0..b {
            let row = &logits[bi * v..(bi + 1) * v];
            let next = clusterfusion::runtime::argmax(row);
            let ref_next = clusterfusion::runtime::argmax(&ref_logits[bi * v..(bi + 1) * v]);
            if ref_next != next {
                let rrow = &ref_logits[bi * v..(bi + 1) * v];
                let mut sorted: Vec<f32> = rrow.to_vec();
                sorted.sort_by(|a, b| b.total_cmp(a));
                assert!(
                    sorted[0] - sorted[1] < 1e-2,
                    "{} n={cluster} step {t}: argmax diverged ({next} vs {ref_next}) with \
                     decisive margin {}",
                    cfg.name,
                    sorted[0] - sorted[1]
                );
            }
            tokens[bi] = next as i32;
            if bi == 0 {
                stream.push(next);
            }
        }
    }
    stream
}

fn tiny_mha() -> ModelConfig {
    ModelConfig {
        name: "tiny-mha-test".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        head_dim: 16,
        ffn_dim: 48,
        max_seq: 32,
        attn: AttnKind::Mha,
        kv_lora_rank: 0,
    }
}

fn tiny_mla() -> ModelConfig {
    ModelConfig {
        name: "tiny-mla-test".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        head_dim: 8,
        ffn_dim: 48,
        max_seq: 16,
        attn: AttnKind::Mla,
        kv_lora_rank: 16,
    }
}

#[test]
fn mha_block_matches_scalar_reference_across_cluster_sizes() {
    for cluster in [1usize, 2, 4] {
        let a = differential_decode(&tiny_mha(), 42, cluster, 6);
        // cluster size is an execution detail: the greedy stream at one
        // seed must not depend on it
        let b = differential_decode(&tiny_mha(), 42, 1, 6);
        assert_eq!(a, b, "cluster {cluster} changed the greedy stream");
    }
}

#[test]
fn micro_llama_block_matches_scalar_reference() {
    let s = differential_decode(&ModelConfig::micro_llama(), 7, 2, 5);
    assert_eq!(s.len(), 5);
}

#[test]
fn mla_block_matches_scalar_reference_across_cluster_sizes() {
    for cluster in [1usize, 2, 4] {
        differential_decode(&tiny_mla(), 42, cluster, 6);
    }
    differential_decode(&ModelConfig::micro_mla(), 7, 2, 4);
}

// ---------------------------------------------------------------------------
// Greedy determinism through the serving engine
// ---------------------------------------------------------------------------

#[test]
fn greedy_engine_decode_is_seed_stable_across_runs() {
    let run = || -> Vec<(u64, Vec<i32>)> {
        let backend = FunctionalBackend::from_model_name("micro-llama", 42, 2).unwrap();
        let clock = VirtualClock::shared();
        let mut engine = Engine::with_clock(backend, 64, 8, 1.0, clock.clone());
        // prompts end in distinct tokens: a random tied-embedding
        // transformer parrots its final prompt token, so this guarantees
        // the four streams cannot trivially coincide
        for id in 0..4u64 {
            engine.submit(Request::new(id, vec![5, 9, 1 + id as i32], 6));
        }
        let mut streams = Vec::new();
        while !engine.idle() {
            engine.step().unwrap();
            clock.advance_us(1_000);
            for ev in engine.take_events() {
                if let Event::Finished { id, generated, .. } = ev {
                    streams.push((id, generated));
                }
            }
        }
        streams.sort();
        streams
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), 4);
    assert_eq!(a, b, "same seed must replay byte-identical token streams");
    // distinct prompts must not all collapse onto one stream
    assert!(a.iter().any(|(_, s)| s != &a[0].1), "streams suspiciously identical");
}

// ---------------------------------------------------------------------------
// Fusion-scope cost properties
// ---------------------------------------------------------------------------

#[test]
fn fusion_scopes_agree_on_flops_and_are_traffic_monotone_everywhere() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let models = [
        ModelConfig::llama2_7b(),
        ModelConfig::deepseek_v2_lite(),
        ModelConfig::head_sweep_variant(128),
        ModelConfig::micro_llama(),
        ModelConfig::micro_mla(),
    ];
    for model in &models {
        for &seq in &[1024usize, 4096, 16384] {
            let seq = seq.min(model.max_seq);
            for &batch in &[1usize, 8] {
                for n in [1usize, 2, 4, 8] {
                    if !block::supports_cluster(model, n) {
                        continue;
                    }
                    let p = BlockProblem::from_model(model, batch, seq);
                    let env = CostEnv::clusterfusion(&hw, &noc, n);
                    let iso = block::cost(&p, FusionScope::BlockIsolated, &env);
                    let att = block::cost(&p, FusionScope::AttentionFused, &env);
                    let ful = block::cost(&p, FusionScope::FullBlockFused, &env);
                    let tag = format!("{} seq={seq} b={batch} n={n}", model.name);
                    // fusion never changes arithmetic
                    assert_eq!(iso.flops, att.flops, "{tag}");
                    assert_eq!(att.flops, ful.flops, "{tag}");
                    assert!(ful.flops > 0.0, "{tag}");
                    // wider scope -> strictly fewer launches, no more HBM
                    assert!(ful.hbm_bytes <= att.hbm_bytes, "{tag}");
                    assert!(att.hbm_bytes <= iso.hbm_bytes, "{tag}");
                    assert_eq!(ful.launches, 1, "{tag}");
                    assert!(att.launches < iso.launches, "{tag}");
                    // the baseline uses no cluster collectives at all
                    assert_eq!(iso.dsmem_bytes, 0.0, "{tag}");
                    if n > 1 {
                        assert!(ful.dsmem_bytes >= att.dsmem_bytes, "{tag}");
                    }
                }
            }
        }
    }
}

#[test]
fn latency_ordering_full_leq_attn_leq_isolated_at_tuned_cluster() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    // (model, tuned N): Fig. 11 optima — N=4 for the 32/16-head paper
    // models, N=2 at 128 heads; the micro models order at every small N.
    let cases = [
        (ModelConfig::llama2_7b(), vec![4usize]),
        (ModelConfig::deepseek_v2_lite(), vec![4]),
        (ModelConfig::head_sweep_variant(128), vec![1, 2, 4]),
        (ModelConfig::micro_llama(), vec![1, 2, 4]),
        (ModelConfig::micro_mla(), vec![1, 2, 4]),
    ];
    for (model, clusters) in &cases {
        for &seq in &[1024usize, 4096, 16384] {
            let seq = seq.min(model.max_seq);
            for &batch in &[1usize, 8] {
                for &n in clusters {
                    let p = BlockProblem::from_model(model, batch, seq);
                    let env = CostEnv::clusterfusion(&hw, &noc, n);
                    let iso = block::cost(&p, FusionScope::BlockIsolated, &env).latency;
                    let att = block::cost(&p, FusionScope::AttentionFused, &env).latency;
                    let ful = block::cost(&p, FusionScope::FullBlockFused, &env).latency;
                    assert!(
                        ful <= att && att <= iso,
                        "{} seq={seq} b={batch} n={n}: {ful} / {att} / {iso}",
                        model.name
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Replay acceptance: functional backend + block-model service costs
// ---------------------------------------------------------------------------

#[test]
fn functional_replay_on_virtual_clock_is_byte_stable() {
    let hw = Hardware::h100_sxm5();
    let noc = Noc::h100(&hw);
    let cfg = ModelConfig::micro_llama();
    let service =
        ServiceModel::from_block(&cfg, cfg.max_seq, FusionScope::FullBlockFused, 2, &hw, &noc);
    assert!(service.step_base_us >= 1);

    let run = || {
        let backend = FunctionalBackend::from_model_name("micro-llama", 42, 2).unwrap();
        let mut engine = Engine::with_clock(backend, 128, 8, 0.5, VirtualClock::shared());
        let trace = Trace::poisson(24, 400.0, SeqlenDist::Fixed(16), (4, 8), 64, 11);
        let requests = loadgen::synthesize_requests(&trace, cfg.vocab, 12, 8, 5);
        loadgen::replay(&mut engine, &requests, &service, 1_000_000).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.completed, 24, "every request must finish");
    assert!(a.tokens_out > 0 && a.steps > 0);
    assert_eq!(
        a.render(),
        b.render(),
        "virtual-clock replay over the functional backend must be byte-deterministic"
    );
    // the block service model must order by fusion scope here too
    let at = |s| ServiceModel::from_block(&cfg, cfg.max_seq, s, 2, &hw, &noc);
    let (iso, att, ful) = (
        at(FusionScope::BlockIsolated),
        at(FusionScope::AttentionFused),
        at(FusionScope::FullBlockFused),
    );
    for live in [1usize, 8] {
        assert!(ful.step_us(live) <= att.step_us(live));
        assert!(att.step_us(live) <= iso.step_us(live));
    }
}
