//! Prefill differential suite (tier-1): the multi-position prefill path
//! must be *byte-identical* to the retired decode-as-prefill behaviour at
//! every chunk size. Feeding a prompt one row per step (`chunk = 1`) is
//! exactly what the old single-token engine did, so it is the frozen
//! baseline; one-shot prefill (`chunk = 0`, the whole prompt in one step)
//! and every intermediate chunking must reproduce its KV planes
//! (`f32::to_bits` over every layer/plane/position), its first token, and
//! its full greedy stream — only the step count may change, and it must be
//! exactly ceil(P/chunk) prefill steps for a P-token prompt.
//!
//! The matrix runs the real functional pipeline (micro-llama MHA +
//! micro-mla MLA) across worker-pool sizes 1 and 4: chunking must be
//! invariant to both the attention family and host threading
//! (DESIGN.md §Prefill, §Parallel). Edge cases — a chunk larger than the
//! prompt, a single-token prompt, and a mid-prefill preemption that must
//! discard fed progress (vLLM recompute semantics) — ride on the mock
//! backend.

use clusterfusion::coordinator::engine::{Backend, Engine, MockBackend};
use clusterfusion::coordinator::request::{Event, Request};
use clusterfusion::coordinator::FunctionalBackend;

/// Everything the prefill refactor is allowed to keep and the one thing
/// it is allowed to change: the byte-level outcome of a request, plus
/// the step count that produced it.
#[derive(Debug, PartialEq, Eq)]
struct Snapshot {
    /// KV rows of every prompt position, captured the moment prefill
    /// completes: `(layer, plane, position, to_bits(row))` flattened.
    kv_bits: Vec<u32>,
    first_token: i32,
    stream: Vec<i32>,
    prefill_steps: u64,
}

fn greedy_stream(events: &[Event]) -> Vec<i32> {
    events
        .iter()
        .filter_map(|ev| match ev {
            Event::FirstToken { token, .. } | Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect()
}

/// Drive one request through an engine at the given chunk: step exactly
/// through prefill, snapshot the KV planes, then decode to completion.
fn snapshot<B: Backend>(backend: B, chunk: usize, prompt: &[i32], gen: usize) -> Snapshot {
    let geom = backend.geom();
    let mut engine = Engine::new(backend, 64, 8, 1.0);
    engine.set_prefill_chunk(chunk);
    engine.submit(Request::new(1, prompt.to_vec(), gen));

    let p = prompt.len();
    let prefill_steps = if chunk == 0 { 1 } else { p.div_ceil(chunk) };
    let expect_steps = prefill_steps as u64;
    while engine.pool.seq_len(1).unwrap_or(0) < p {
        assert!(engine.step().unwrap(), "engine stalled mid-prefill");
        assert!(engine.steps <= expect_steps, "prefill overran ceil(P/chunk)");
    }
    assert_eq!(engine.steps, expect_steps, "P={p} chunk={chunk}");
    assert_eq!(engine.pool.seq_len(1), Some(p), "no decode rows may land early");
    // the final prompt chunk already sampled the first token
    assert_eq!(engine.tokens_out, 1);

    let mut kv_bits = Vec::new();
    for layer in 0..geom.n_layers {
        for plane in 0..geom.planes {
            for pos in 0..p {
                let row = engine.pool.peek(1, pos, layer, plane).expect("prompt row present");
                kv_bits.extend(row.iter().map(|v| v.to_bits()));
            }
        }
    }

    engine.run_to_completion(1_000).unwrap();
    let events = engine.take_events();
    let stream = greedy_stream(&events);
    assert_eq!(stream.len(), gen);
    Snapshot { kv_bits, first_token: stream[0], stream, prefill_steps: expect_steps }
}

const PROMPT: [i32; 7] = [3, 5, 9, 2, 11, 4, 7];
const GEN: usize = 5;

#[test]
fn chunked_prefill_matches_decode_as_prefill_byte_for_byte() {
    // chunk 1 == the retired engine (one prompt row per step): everything
    // else must reproduce it exactly, on both attention families and at
    // both worker-pool sizes.
    for model in ["micro-llama", "micro-mla"] {
        for threads in [1usize, 4] {
            let make = || FunctionalBackend::from_model_name_on(model, 42, 2, threads).unwrap();
            let baseline = snapshot(make(), 1, &PROMPT, GEN);
            assert_eq!(baseline.prefill_steps, 7);
            for chunk in [3usize, 0] {
                let got = snapshot(make(), chunk, &PROMPT, GEN);
                assert_eq!(
                    got.kv_bits, baseline.kv_bits,
                    "{model} t{threads} chunk={chunk}: KV planes diverged"
                );
                assert_eq!(got.first_token, baseline.first_token, "{model} t{threads}");
                assert_eq!(
                    got.stream, baseline.stream,
                    "{model} t{threads} chunk={chunk}: greedy stream diverged"
                );
            }
        }
    }
}

#[test]
fn thread_pool_size_never_changes_prefill_bytes() {
    // the same (model, seed, chunk) must produce identical bytes at pool
    // sizes 1 and 4 — threading is a wall-clock knob only
    for model in ["micro-llama", "micro-mla"] {
        for chunk in [1usize, 3, 0] {
            let at = |threads| {
                snapshot(
                    FunctionalBackend::from_model_name_on(model, 42, 2, threads).unwrap(),
                    chunk,
                    &PROMPT,
                    GEN,
                )
            };
            assert_eq!(at(1), at(4), "{model} chunk={chunk}");
        }
    }
}

#[test]
fn chunk_larger_than_prompt_is_one_shot() {
    let base = snapshot(MockBackend::tiny(), 0, &[3, 5, 9], 4);
    let big = snapshot(MockBackend::tiny(), 64, &[3, 5, 9], 4);
    assert_eq!(base.prefill_steps, 1);
    assert_eq!(big.prefill_steps, 1, "an oversized chunk must not pad steps");
    assert_eq!(base, big);
}

#[test]
fn single_token_prompt_prefills_in_one_step_at_every_chunk() {
    let mut snaps = Vec::new();
    for chunk in [0usize, 1, 5] {
        let s = snapshot(MockBackend::tiny(), chunk, &[7], 4);
        assert_eq!(s.prefill_steps, 1, "chunk={chunk}");
        snaps.push(s);
    }
    assert!(snaps.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn mid_prefill_preemption_discards_fed_progress() {
    // 3 pages × 4 tokens = 12 KV slots. Request 1 (prompt 4 + gen 8 = 12
    // slots) fills the pool alone, so request 2 (prompt 8 + gen 4) is
    // preempted mid-prefill — after feeding its first chunk but before
    // finishing the prompt — and must restart from row 0 when readmitted
    // (vLLM recompute preemption: fed progress is discarded with the
    // pages). The regenerated outcome must match an unpressured run.
    let run = |pages: usize| {
        let mut e = Engine::new(MockBackend::tiny(), pages, 4, 0.5);
        e.set_prefill_chunk(4);
        e.submit(Request::new(1, vec![2; 4], 8));
        e.submit(Request::new(2, vec![3; 8], 4));
        e.run_to_completion(1_000).unwrap();
        let mut streams: Vec<(u64, Vec<i32>)> = e
            .take_events()
            .into_iter()
            .filter_map(|ev| match ev {
                Event::Finished { id, generated, .. } => Some((id, generated)),
                _ => None,
            })
            .collect();
        streams.sort();
        assert_eq!(e.pool.used_pages(), 0, "all pages returned");
        (streams, e.preemptions, e.prefill_tokens)
    };
    let (pressured, preemptions, prefill_rows) = run(3);
    let (free, no_preemptions, free_rows) = run(64);
    assert_eq!(no_preemptions, 0);
    assert_eq!(free_rows, 12, "unpressured: each prompt row fed exactly once");
    assert!(preemptions > 0, "the 3-page pool must preempt");
    assert!(
        prefill_rows > 12,
        "a mid-prefill victim must re-feed discarded rows: {prefill_rows}"
    );
    assert_eq!(pressured, free, "recompute preemption must not change any stream");
}
