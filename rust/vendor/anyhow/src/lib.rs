//! Offline, in-tree subset of the `anyhow` API.
//!
//! The build is fully offline (DESIGN.md §2), so instead of the crates.io
//! `anyhow` this path crate provides the exact surface the repository
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros. Error
//! values carry a message plus a context chain; `{e}` prints the outermost
//! message, `{e:#}` the full chain, `{e:?}` an anyhow-style report.

use std::fmt;

/// Error type: an outermost message plus the chain of underlying causes
/// (outermost first, root cause last).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` macro's backend).
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated (anyhow convention).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any `std` error converts into [`Error`], capturing its source chain.
/// (`Error` itself deliberately does not implement `std::error::Error`,
/// exactly like the real anyhow, so this blanket impl is coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Unifies "already an [`crate::Error`]" and "a std error" for the
    /// [`crate::Context`] impl without overlapping blanket impls.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            self.into()
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` (any error kind, including [`Error`] itself) and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    fn parse_num(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?;
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fail_io().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
        assert_eq!(parse_num("41").unwrap(), 41);
        assert!(parse_num("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = fail_io().context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let got: Result<u32> = Some(7u32).with_context(|| "unused");
        assert_eq!(got.unwrap(), 7);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.with_context(|| format!("outer {}", 9)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 9: inner 3");
    }

    fn guarded(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        ensure!(x < 100);
        if x == 13 {
            bail!("unlucky {x}");
        }
        Ok(x)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(guarded(5).unwrap(), 5);
        assert_eq!(guarded(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert!(guarded(200).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(guarded(13).unwrap_err().to_string(), "unlucky 13");
    }
}
